"""Hypothesis property tests for the paper's protocol invariants.

Random operation sequences (mmap / touch / mprotect / munmap / migrate,
from random nodes) are applied under every policy; after every batch we
check the simulator's full invariant suite:

  I1  owner/canonical copy holds every valid PTE of its tables
  I2  a TLB on node n caches vpn  =>  n is a sharer of vpn's leaf table
  I3  TLB contents agree with the flat oracle (no stale translations)
  I4  unmapped vpns appear in no TLB
plus: numaPTE footprint <= Mitosis footprint; numaPTE shootdown targets
are a subset of the unfiltered target set.

All four invariants are checked *per address space* (``check_invariants``
walks every ``(cpu, asid)`` TLB partition against its own process's
tables and oracle), so the multi-process properties below run the same
random programs in two tenants sharing every CPU: I2/I4 must hold for
each ASID independently, one tenant's munmap must never drop — or leave
— entries in the other tenant's tagged partitions, and the per-process
oracles stay disjoint even over identical VPN ranges.
"""
from __future__ import annotations

import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need the 'test' extra "
    "(pip install -e .[test])")

import hypothesis.strategies as st
from hypothesis import HealthCheck, given, settings

from repro.core import NumaSim, NumaTopology, Policy, SegfaultError
from repro.core.pagetable import PERM_R, PERM_RW

TOPO = NumaTopology(n_nodes=4, cores_per_node=4, threads_per_core=1)

op_strategy = st.lists(
    st.tuples(
        st.sampled_from(["mmap", "touch", "mprotect", "munmap", "migrate"]),
        st.integers(0, 3),          # acting thread index
        st.integers(0, 7),          # vma index / page offset selector
        st.integers(1, 24),         # size in pages
    ),
    min_size=5, max_size=60)


def build_sim(policy: Policy, prefetch: int, tlb_filter: bool) -> NumaSim:
    sim = NumaSim(TOPO, policy, prefetch_degree=prefetch,
                  tlb_filter=tlb_filter, tlb_entries=64)
    for node in range(TOPO.n_nodes):
        sim.spawn_thread(node * TOPO.hw_threads_per_node)
    return sim


def build_two_tenant_sim(policy: Policy, prefetch: int,
                         tlb_filter: bool) -> tuple:
    """One sim, two address spaces, both resident on every CPU — the
    shared-CPU colocation the ASID-tagged TLB partitions exist for."""
    sim = NumaSim(TOPO, policy, prefetch_degree=prefetch,
                  tlb_filter=tlb_filter, tlb_entries=64)
    other = sim.spawn_process("tenant")
    for node in range(TOPO.n_nodes):
        sim.spawn_thread(node * TOPO.hw_threads_per_node)
        sim.spawn_thread(node * TOPO.hw_threads_per_node, process=other)
    return sim, other


def apply_ops(sim: NumaSim, ops, tids=None) -> None:
    vmas = []
    tids = list(tids) if tids is not None else list(sim.threads)
    for kind, ti, sel, size in ops:
        tid = tids[ti % len(tids)]
        if kind == "mmap":
            vmas.append(sim.mmap(tid, size))
        elif kind == "touch" and vmas:
            vma = vmas[sel % len(vmas)]
            if vma.n_pages > 0:
                vpn = vma.start_vpn + (sel * 7 + size) % vma.n_pages
                try:
                    sim.touch(tid, vpn, write=bool(size % 2))
                except SegfaultError:
                    pass       # racing munmap carved this vma
        elif kind == "mprotect" and vmas:
            vma = vmas[sel % len(vmas)]
            n = min(size, vma.n_pages)
            if n > 0:
                sim.mprotect(tid, vma.start_vpn, n,
                             PERM_R if size % 2 else PERM_RW)
        elif kind == "munmap" and vmas:
            vma = vmas.pop(sel % len(vmas))
            sim.munmap(tid, vma.start_vpn, vma.n_pages)
        elif kind == "migrate":
            cpu = (sel * 3 + size) % sim.topo.total_hw_threads
            sim.migrate_thread(tid, cpu)
        sim.check_invariants()


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=op_strategy,
       policy=st.sampled_from(list(Policy)),
       prefetch=st.sampled_from([0, 3, 9]),
       tlb_filter=st.booleans())
def test_invariants_random_ops(ops, policy, prefetch, tlb_filter):
    sim = build_sim(policy, prefetch, tlb_filter)
    apply_ops(sim, ops)
    sim.check_invariants()


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=op_strategy,
       policy=st.sampled_from(list(Policy)),
       tlb_filter=st.booleans())
def test_invariants_random_ops_two_tenants(ops, policy, tlb_filter):
    """I1-I4 hold per address space when two tenants run the same random
    program on shared CPUs: every (cpu, asid) partition is checked
    against its own process's tables/oracle after every op."""
    sim, other = build_two_tenant_sim(policy, 0, tlb_filter)
    apply_ops(sim, ops, tids=list(sim.processes[0].threads))
    apply_ops(sim, ops, tids=list(other.threads))
    sim.check_invariants()


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=op_strategy, policy=st.sampled_from(list(Policy)))
def test_munmap_isolates_address_spaces(ops, policy):
    """Tagged I2/I4 across tenants: after one tenant unmaps its entire
    address space, no CPU holds any of that tenant's ASID-tagged
    translations — while the co-resident tenant's TLB entries and oracle
    are byte-for-byte untouched (invalidation is tag-selective)."""
    sim, other = build_two_tenant_sim(policy, 0, True)
    apply_ops(sim, ops, tids=list(sim.processes[0].threads))
    apply_ops(sim, ops, tids=list(other.threads))
    other_tlbs = {cpu: list(tlb.entries.items())
                  for cpu, tlb in sim._asid_tlbs[other.asid].items()}
    other_oracle = dict(other.oracle)
    a_tid = next(iter(sim.processes[0].threads))
    for vma in list(sim.vmas):
        sim.munmap(a_tid, vma.start_vpn, vma.n_pages)
    assert not sim.processes[0].oracle
    for cpu, tlb in sim._asid_tlbs[0].items():
        assert not tlb.entries, \
            f"cpu {cpu} still holds ASID-0 entries after full munmap"
    assert dict(other.oracle) == other_oracle
    for cpu, tlb in sim._asid_tlbs[other.asid].items():
        assert list(tlb.entries.items()) == other_tlbs.get(cpu, []), \
            f"tenant partition on cpu {cpu} disturbed by foreign munmap"
    sim.check_invariants()


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=op_strategy)
def test_footprint_ordering(ops):
    """numaPTE's replica footprint never exceeds Mitosis's."""
    sims = {p: build_sim(p, 3, True)
            for p in (Policy.LINUX, Policy.MITOSIS, Policy.NUMAPTE)}
    for sim in sims.values():
        apply_ops(sim, ops)
    linux = sims[Policy.LINUX].pt_footprint_bytes()
    mitosis = sims[Policy.MITOSIS].pt_footprint_bytes()
    numapte = sims[Policy.NUMAPTE].pt_footprint_bytes()
    assert linux <= numapte <= mitosis


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=op_strategy)
def test_filter_only_removes_ipis(ops):
    """The TLB filter may only ever REDUCE the IPI count, and the sum of
    sent+filtered equals the unfiltered count (same op sequence)."""
    a = build_sim(Policy.NUMAPTE, 0, True)
    b = build_sim(Policy.NUMAPTE, 0, False)
    apply_ops(a, ops)
    apply_ops(b, ops)
    sent_a = a.counters.ipis_local + a.counters.ipis_remote
    sent_b = b.counters.ipis_local + b.counters.ipis_remote
    assert sent_a + a.counters.ipis_filtered == sent_b
    assert sent_a <= sent_b


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=op_strategy, degree=st.sampled_from([1, 4, 9]))
def test_prefetch_changes_no_semantics(ops, degree):
    """Prefetching must not change any translation the oracle sees, only
    the miss counts (paper Sec 3.4.1: prefetch is coherence-free)."""
    lazy = build_sim(Policy.NUMAPTE, 0, True)
    eager = build_sim(Policy.NUMAPTE, degree, True)
    apply_ops(lazy, ops)
    apply_ops(eager, ops)
    assert lazy._oracle == eager._oracle
    assert eager.counters.pte_copies <= lazy.counters.pte_copies or \
        eager.counters.pte_prefetched >= 0
